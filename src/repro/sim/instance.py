"""Simulated inference instance: iteration-level continuous batching.

Mirrors the serving engine's execution model (§2.2 + DESIGN.md §Chunked
prefill): at each iteration the instance admits waiting requests under
its token-memory budget (batch cap 1024) and advances every
fully-prefilled request by one token. With ``prefill_budget`` set, the
iteration is **mixed** exactly like ``serving.Engine``: up to that many
prompt-chunk tokens (oldest request first) prefill beside the full decode
batch, priced by ``costmodel.mixed_iter_time`` — a long prompt stretches
across many iterations instead of freezing the batch, and its request
produces its first token only when the last chunk lands. With
``prefill_budget=None`` the legacy whole-prompt model applies: admission
prefills the entire prompt in the admission iteration
(``costmodel.prefill_time``) — the §2.1 head-of-line baseline.

Simplifications vs. vLLM (noted in DESIGN.md): admission reserves the
prompt only (no preemption/swap on overflow — outputs are finite and the
budget check keeps overflow marginal).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.core.migration import MigrationManager
from repro.sched.slo import (aging_promotion, insert_sorted, priority_of,
                             queue_key, tpot_hopeless)
from repro.serving.block_pool import blocks_for
from repro.sim.costmodel import (HardwareProfile, decode_iter_time,
                                 demote_time, mixed_iter_time, prefill_time,
                                 promote_time)
from repro.sim.workload import Request

BATCH_CAP = 1024   # vLLM official default (paper §6.1)
KV_BLOCK_SIZE = 16  # paged-cache allocation unit (mirrors serving.Engine)


@dataclasses.dataclass
class SimRequest:
    req: Request
    length: int                      # current sequence length
    generated: int = 0
    # prefill progress (chunked instances): prompt tokens written to
    # cache. Monolithic instances set it to input_len at admission; a
    # migrated half-prefilled request carries it to the receiver.
    ctx_done: int = 0
    # prompt tokens backed by the instance's shared prefix store
    # (block-aligned, mirrors ServeRequest.cached_tokens): these blocks
    # are counted once per group, not once per sharer, and their prefill
    # never runs. Reset to 0 on migration — a shared prefix re-imports
    # as private (DESIGN.md §Prefix cache).
    cached_tokens: int = 0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    migrating: bool = False
    rejected: bool = False           # oversized for any instance: failed
    # fault tolerance (DESIGN.md §Fault tolerance): failed = retry budget
    # exhausted after its instance died (excluded from `served` like
    # rejected); redispatches = dead-instance recoveries this request
    # survived (each replays prompt + generated-so-far elsewhere)
    failed: bool = False
    redispatches: int = 0
    # per-instance output-token counts (paper Fig. 16 CV metric)
    tokens_by_instance: Dict[int, int] = dataclasses.field(default_factory=dict)
    # batch-feature accumulators for QoE profiling (avg loads over lifetime)
    feat_sum: List[float] = dataclasses.field(
        default_factory=lambda: [0.0] * 5)
    feat_iters: int = 0
    # --- SLO scheduling & preemption (mirrors ServeRequest) ---
    # recompute-preemption resume state: rows chunked prefill must rebuild
    # (= prompt + generated-so-far minus the pending last token) before
    # decoding continues. None = not resuming.
    resume_target: Optional[int] = None
    # waiting-queue sort key (repro.sched.slo.queue_key)
    sched_key: Optional[tuple] = None
    preemptions: int = 0
    # starvation/aging guard (mirrors ServeRequest.preempted_step): sim
    # time of the recompute preemption that re-enqueued this request
    preempted_t: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.generated >= self.req.output_len

    @property
    def prefill_target_len(self) -> int:
        """Rows prefill must write before decode (re)starts."""
        return (self.resume_target if self.resume_target is not None
                else self.req.input_len)

    @property
    def prefilling(self) -> bool:
        return self.ctx_done < self.prefill_target_len

    @property
    def kv_len(self) -> int:
        """Cache rows that physically exist: the written prompt part plus
        every generated token (= ``length`` once prefill is done). This —
        not the full ``length`` — is what pins memory and what a
        migration ships. Mid-recompute only the rebuilt rows exist."""
        if self.resume_target is not None:
            return self.ctx_done
        return self.ctx_done + self.generated

    @property
    def normalized_latency(self) -> float:
        assert self.finish_t is not None
        return (self.finish_t - self.req.arrival) / max(self.req.output_len, 1)

    @property
    def ttft(self) -> float:
        assert self.first_token_t is not None
        return self.first_token_t - self.req.arrival

    @property
    def tpot(self) -> float:
        assert self.finish_t is not None and self.first_token_t is not None
        return ((self.finish_t - self.first_token_t)
                / max(self.req.output_len - 1, 1))


class Instance:
    def __init__(self, inst_id: int, profile: HardwareProfile,
                 capacity_tokens: float, events, *,
                 batch_cap: int = BATCH_CAP,
                 block_size: int = KV_BLOCK_SIZE,
                 prefill_budget: Optional[int] = None,
                 prefix_cache: bool = True,
                 preemption: bool = False,
                 host_kv_blocks: int = 0):
        self.id = inst_id
        self.profile = profile
        self.block_size = block_size
        # chunked mixed iterations (DESIGN.md §Chunked prefill); None =
        # legacy monolithic prefill-at-admission
        self.prefill_budget = prefill_budget
        self._iter_chunks: List = []     # (sr, chunk_len) planned this iter
        # group-granular prefix-cache mirror (DESIGN.md §Prefix cache):
        # prefix_group -> shareable blocks, published when a group member
        # finishes prefill. Mirrors the engine's content-hashed index at
        # the granularity the workload generator defines; needs chunked
        # iterations (warm admissions resume mid-prompt). Unreferenced
        # entries cost nothing (the real allocator parks them reclaimable
        # = free); sim runs never model reclaim-under-pressure.
        self.prefix_cache = prefix_cache and prefill_budget is not None
        self._prefix_store: Dict[int, int] = {}
        # multi-tier KV mirror (DESIGN.md §Multi-tier KV): with a host
        # budget, published groups whose blocks have NO live sharer pin
        # device capacity (the engine's refcount-0 parked chains) until
        # memory-pressure demotes them — group-granular — into
        # ``_host_store`` (insertion order = LRU, capacity-bounded in
        # blocks). A later hit promotes the group back, charging the h2d
        # staging time. host_kv_blocks == 0 keeps the legacy model
        # bit-for-bit: idle published groups cost nothing and are never
        # demoted (the sim's old no-reclaim simplification).
        self.host_kv_blocks = int(host_kv_blocks) if self.prefix_cache else 0
        self._host_store: Dict[int, int] = {}
        self.cache_demotions = 0
        self.cache_drops = 0
        self.cache_promotions = 0
        self.promoted_blocks_total = 0
        self._tier_io_s = 0.0    # staged copies charged to this iteration
        # capacity is block-granular: what a paged allocator can actually
        # hand out (tokens that don't fill a block can't back any request)
        self.capacity_blocks = int(capacity_tokens // block_size)
        self.capacity = float(self.capacity_blocks * block_size)
        self.events = events
        self.batch_cap = batch_cap
        self.waiting: Deque[SimRequest] = deque()
        self.running: List[SimRequest] = []
        # SLO-tiered preemptive scheduling (mirrors serving.Engine): off =
        # bit-parity FCFS legacy. Parked requests hold KV (counted by
        # kv_blocks) but no batch seat.
        self.slo_sched = bool(preemption)
        self.parked: List[SimRequest] = []
        self._seq = 0
        self.preemptions = 0
        self.preempt_recomputes = 0
        self.resumes = 0
        # TPOT-deadline admission (mirrors serving.Engine.tpot_skipped)
        self.tpot_skipped = 0
        self._tpot_hopeless_ids: set = set()
        self.iterating = False
        self.migrations = MigrationManager()
        self.inbound_reserved = 0.0      # tokens reserved for inbound transfers
        # ---- fault state (DESIGN.md §Fault tolerance) ----
        self.alive = True
        # epoch fences stale events: crash bumps it, and a pre-crash
        # iteration-end callback from the event queue no-ops instead of
        # mutating the revived instance
        self.epoch = 0
        self.slowdown = 1.0              # iteration-duration multiplier
        self._down_since: Optional[float] = None
        self.downtime_total = 0.0
        # hooks set by the cluster/policy
        self.on_iteration_end: Optional[Callable] = None
        self.on_request_done: Optional[Callable] = None
        # accounting
        self.busy_until = 0.0
        self.tokens_out = 0
        self.throughput_est = 1000.0     # tokens/s EMA (bid payloads)

    # ---- load views -------------------------------------------------------
    def kv_blocks(self) -> int:
        """Physical cache blocks allocated to running requests + inbound
        transfers: each request pins ceil(length/BS) blocks — the paged
        allocator's true memory pressure (matches serving.Engine), which is
        what bid-ask and refinement accounting see. Waiting requests hold
        NO cache (vLLM semantics) — counting them against the budget
        deadlocks admission under tight memory."""
        bs = self.block_size
        # inbound_reserved is a sum of already block-rounded per-transfer
        # amounts (cluster reserves block_tokens(length) per migration), so
        # dividing the total keeps per-transfer granularity. Resident
        # requests pin kv_len (not length): a half-prefilled prompt pins
        # only its written blocks — and a group's shared prefix blocks
        # pin ONCE, no matter how many sharers reference them (the deepest
        # live sharer defines the resident depth, mirroring the refcounted
        # allocator where blocks beyond it are refcount-0 reclaimable).
        shared_depth: Dict[int, int] = {}
        private = 0
        for r in self.running + self.parked:    # parked KV stays pinned
            cb = r.cached_tokens // bs
            private += blocks_for(r.kv_len, bs) - cb
            if cb:
                g = r.req.prefix_group
                shared_depth[g] = max(shared_depth.get(g, 0), cb)
        if self.host_kv_blocks > 0:
            # tiered model: a published group's FULL chain stays device-
            # resident (the engine's refcount-0 parked blocks) until a
            # memory-pressure demote moves it to the host tier — so idle
            # prefixes genuinely pin capacity, exactly what makes
            # demotion fire under the same pressure the engine feels
            for g, blocks in self._prefix_store.items():
                shared_depth[g] = max(shared_depth.get(g, 0), blocks)
        return (private + sum(shared_depth.values())
                + blocks_for(self.inbound_reserved, bs))

    def kv_tokens(self) -> float:
        """Block-rounded tokens of cache memory held (allocation
        granularity, not raw sequence lengths)."""
        return float(self.kv_blocks() * self.block_size)

    def block_tokens(self, tokens: float) -> float:
        """Round a token count up to the allocator's block granularity."""
        return float(blocks_for(tokens, self.block_size) * self.block_size)

    def mem_tokens(self) -> float:
        return self.kv_tokens()

    def free_tokens(self) -> float:
        return float((self.capacity_blocks - self.kv_blocks())
                     * self.block_size)

    def queued_tokens(self) -> float:
        """UN-PREFILLED, UNCACHED prompt tokens: whole waiting prompts
        (minus their prefix-store hit, estimated at enqueue) plus the
        unwritten remainder of running requests mid-chunked-prefill
        (mirrors ``serving.Engine.queued_tokens``)."""
        return float(sum(r.prefill_target_len - r.cached_tokens
                         for r in self.waiting)
                     + sum(r.prefill_target_len - r.ctx_done
                           for r in self.running if r.prefilling))

    def load(self) -> float:
        """Token-level load (LoadTracker metric): KV pressure + queue."""
        return self.kv_tokens() + self.queued_tokens()

    def request_view(self) -> List:
        """(input_len, current_len) pairs for refinement exchanges."""
        return [(float(r.req.input_len), float(r.length))
                for r in self.running]

    # ---- prefix cache (DESIGN.md §Prefix cache) ----------------------------
    def cached_tokens_for(self, sr: SimRequest) -> int:
        """Prompt tokens this instance's prefix stores — device OR host
        tier — could serve right now (block-aligned; capped so >= 1
        token always re-prefils — mirrors the engine's capped tiered
        chain lookup). A host-tier hit skips the same prefill work; it
        just pays the promote staging time at admission."""
        g = sr.req.prefix_group
        if not self.prefix_cache or g < 0:
            return 0
        blocks = self._prefix_store.get(g)
        if blocks is None:
            blocks = self._host_store.get(g)
        if blocks is None:
            return 0
        cap = (sr.req.input_len - 1) // self.block_size
        return min(blocks, cap) * self.block_size

    def host_blocks_for(self, sr: SimRequest) -> int:
        """Blocks a hit by ``sr`` would have to PROMOTE from the host
        tier (0 for device-resident or missing groups) — the quantity
        tier-aware routing prices via ``promote_cost_tokens``."""
        g = sr.req.prefix_group
        if not self.prefix_cache or g < 0 or g not in self._host_store:
            return 0
        cap = (sr.req.input_len - 1) // self.block_size
        return min(self._host_store[g], cap)

    def prefix_digests(self) -> frozenset:
        """Published prefix groups (either tier) — the sim's analogue of
        the engine's head-digest advertisement."""
        return frozenset(self._prefix_store) | frozenset(self._host_store)

    def tiered_digests(self) -> Dict[int, str]:
        """group -> "device"|"host" (single-tier residence: a group lives
        in exactly one store). Mirrors ``Engine.tiered_digests``."""
        out = {g: "device" for g in self._prefix_store}
        for g in self._host_store:
            out.setdefault(g, "host")
        return out

    def _live_shared_depth(self, group: int) -> int:
        """Deepest live sharer's cached blocks for ``group`` — prefix
        blocks beyond it have refcount 0 in the engine (parked), so an
        admission that uses them must pay their revival."""
        bs = self.block_size
        return max((r.cached_tokens // bs for r in self.running + self.parked
                    if r.req.prefix_group == group), default=0)

    def _publish_prefix(self, sr: SimRequest) -> None:
        """A group member finished prefill: its shared prefix becomes
        servable (first publisher wins; its own prefix blocks convert
        from private to shared accounting, mirroring the engine where
        sharers reference the publisher's physical blocks)."""
        g = sr.req.prefix_group
        if not self.prefix_cache or g < 0 or g in self._prefix_store:
            return
        blocks = sr.req.prefix_len // self.block_size
        if blocks <= 0:
            return
        self._prefix_store[g] = blocks
        self._host_store.pop(g, None)   # single-tier residence
        sr.cached_tokens = max(sr.cached_tokens, blocks * self.block_size)

    # ---- multi-tier KV (DESIGN.md §Multi-tier KV) --------------------------
    def _demote_idle_prefixes(self, keep_group: int) -> bool:
        """Memory-pressure reclaim mirror: the engine's allocator, out of
        free blocks, reclaims refcount-0 cached chains — demoting them
        to the host tier. Group-granular here: every published group
        with no live sharer (except the admission candidate's own) moves
        to the host store, freeing its device blocks. Returns True if
        anything was demoted (caller retries the admission gate, exactly
        like the allocator's reclaim-then-allocate)."""
        if self.host_kv_blocks <= 0:
            return False
        freed = False
        for g in list(self._prefix_store):
            if g == keep_group:
                continue
            if any(r.req.prefix_group == g and r.cached_tokens > 0
                   for r in self.running + self.parked):
                continue               # live sharers pin the chain
            self._host_put(g, self._prefix_store.pop(g))
            freed = True
        return freed

    def _host_put(self, g: int, blocks: int) -> None:
        """Insert a demoted group into the capacity-bounded host store
        (LRU eviction destroys whole groups — the store's analogue of the
        engine's subtree drops)."""
        if blocks > self.host_kv_blocks:
            self.cache_drops += 1      # can never fit: destroyed outright
            return
        while (sum(self._host_store.values()) + blocks
               > self.host_kv_blocks):
            self._host_store.pop(next(iter(self._host_store)))
            self.cache_drops += 1
        self._host_store[g] = blocks
        self.cache_demotions += 1
        self._tier_io_s += demote_time(blocks, self.profile,
                                       self.block_size)

    def _promote_group(self, sr: SimRequest) -> None:
        """An admission hit a host-resident group: stage its blocks back
        to the device tier, charging the h2d copy to this iteration (the
        engine overlaps the copy with the running mixed iteration; the
        sim charges the same staging time into the iteration length)."""
        if self.host_blocks_for(sr) <= 0:
            return
        g = sr.req.prefix_group
        blocks = self._host_store.pop(g)
        self._prefix_store[g] = blocks
        self.cache_promotions += 1
        self.promoted_blocks_total += blocks
        self._tier_io_s += promote_time(blocks, self.profile,
                                        self.block_size)

    # ---- request intake ---------------------------------------------------
    def enqueue(self, sr: SimRequest, t: float) -> None:
        # prefix-hit hint for queued_tokens/load while the request waits
        # (refreshed authoritatively at admission)
        sr.cached_tokens = self.cached_tokens_for(sr)
        if self.slo_sched:
            self._seq += 1
            sr.sched_key = queue_key(
                sr.req.slo_class, sr.req.arrival,
                sr.req.input_len + sr.req.output_len, self._seq)
            insert_sorted(self.waiting, sr)
        else:
            self.waiting.append(sr)
        self.kick(t)

    def adopt_running(self, sr: SimRequest, t: float) -> None:
        """Receive a migrated (still-decoding) request."""
        self.running.append(sr)
        self.kick(t)

    # ---- faults (DESIGN.md §Fault tolerance) --------------------------------
    def crash(self, t: float) -> None:
        """Hard-kill: all resident state is lost. The control plane's
        liveness machinery discovers the death (heartbeats stop) and
        recovers the residents; ``clear_crashed`` wipes the carcass."""
        self.alive = False
        self.epoch += 1                  # fence queued iteration-end events
        self.iterating = False
        self._down_since = t

    def revive(self, t: float) -> None:
        """Rejoin empty (state was wiped at death)."""
        self.alive = True
        if self._down_since is not None:
            self.downtime_total += t - self._down_since
            self._down_since = None

    def downtime_s(self, now: float) -> float:
        extra = (now - self._down_since) if self._down_since is not None \
            else 0.0
        return self.downtime_total + extra

    def clear_crashed(self) -> None:
        """Wipe every resident structure (ClusterOps.instance_down): the
        KV, queues and transfer reservations died with the process."""
        self.waiting.clear()
        self.running.clear()
        self.parked.clear()
        self._prefix_store.clear()
        self._host_store.clear()
        self._tier_io_s = 0.0
        self._iter_chunks = []
        self.inbound_reserved = 0.0
        self.migrations = MigrationManager()
        self.iterating = False

    # ---- iteration machinery ----------------------------------------------
    def kick(self, t: float) -> None:
        if not self.alive:
            return
        if self.iterating or (not self.waiting and not self.running
                              and not self.parked):
            return
        self.iterating = True
        self._start_iteration(t)

    def _start_iteration(self, t: float) -> None:
        admitted: List[SimRequest] = []
        if self.slo_sched:
            self._age_waiting(t)
            self._resume_ready()
        chunks: List = []                       # (sr, chunk_len) this iter
        budget = self.prefill_budget
        if budget is not None:
            # resume in-progress chunked prefills, oldest admitted first
            for r in self.running:
                if budget <= 0:
                    break
                if not r.prefilling:
                    continue
                c = min(r.prefill_target_len - r.ctx_done, budget)
                chunks.append((r, c))
                budget -= c
        # unwritten backlog of already-admitted prompts: their rows are
        # not in kv_blocks yet (chunks land at iteration END), but they
        # WILL materialize — admission must reserve for them or chunked
        # instances could blow past capacity (the engine reserves worst
        # case at admission; this is the sim's equivalent gate)
        pending = sum(r.prefill_target_len - r.ctx_done
                      for r in self.running if r.prefilling)
        while self.waiting:
            if len(self.running) >= self.batch_cap:
                # full batch: a higher-class head may park the lowest-
                # class resident decode (KV pinned, seat freed)
                if not (self.slo_sched
                        and not self._tpot_guard(self.waiting[0], t)
                        and self._preempt_seat(self.waiting[0])):
                    break
                continue
            if self.waiting[0].length + 1 > self.capacity:
                # request can never fit this instance: reject (real
                # engines fail such requests instead of wedging FCFS)
                sr = self.waiting.popleft()
                sr.rejected = True
                sr.finish_t = t
                sr.first_token_t = t
                if self.on_request_done:
                    self.on_request_done(self, sr, t)
                continue
            if budget is not None and budget <= 0:
                break
            # cached admission (DESIGN.md §Prefix cache): the shared
            # prefix is already resident, so only the uncached tail needs
            # room — and only it ever prefills (ctx_done starts there).
            # Prefix blocks with NO live sharer are parked (free
            # capacity), so admitting revives them: charge the revival
            # like the engine's revival_cost, or the sim would admit past
            # capacity where the server refuses.
            head = self.waiting[0]
            cached = self.cached_tokens_for(head)
            if self.host_kv_blocks > 0:
                # tiered accounting: device-resident chains already pin
                # their blocks in kv_blocks (no revival charge), but a
                # host-tier hit must find device room for the blocks it
                # promotes
                revived = self.host_blocks_for(head) * self.block_size
            else:
                revived = max(0, cached - self._live_shared_depth(
                    head.req.prefix_group) * self.block_size)
            if self.free_tokens() < (
                    self.block_tokens(head.length - cached)
                    + revived + pending):
                # memory-blocked: first reclaim like the engine — demote
                # idle published chains to the host tier and retry —
                # then recompute-preempt the lowest-class victim's KV
                if self._demote_idle_prefixes(head.req.prefix_group):
                    continue
                if not (self.slo_sched and not self._tpot_guard(head, t)
                        and self._preempt_mem(head, t)):
                    break
                continue
            sr = self.waiting.popleft()
            self._promote_group(sr)        # host hit: stage blocks back
            sr.cached_tokens = cached
            sr.ctx_done = max(sr.ctx_done, cached)
            self.running.append(sr)
            admitted.append(sr)
            if budget is None:
                sr.resume_target = None             # monolithic re-prefill
                sr.ctx_done = sr.req.input_len      # monolithic prefill
            else:
                pending += sr.prefill_target_len - sr.ctx_done
                c = min(sr.prefill_target_len - sr.ctx_done, budget)
                chunks.append((sr, c))
                budget -= c
        if self.slo_sched:
            self._resume_ready()
        if self.prefill_budget is None:
            decoding = [r for r in self.running if r not in admitted]
            dur = sum(prefill_time(r.length, self.profile) for r in admitted)
            if decoding:
                dur += decode_iter_time([r.length for r in decoding],
                                        self.profile)
        else:
            # mixed iteration: the decode batch (every fully-prefilled
            # request) + the packed prompt chunks, one fused step
            decoding = [r for r in self.running if not r.prefilling]
            dur = mixed_iter_time([(c, r.ctx_done) for r, c in chunks],
                                  [r.length for r in decoding], self.profile)
        if not self.running:
            self.iterating = False
            return
        dur *= self.slowdown             # slow-instance degradation fault
        dur += self._tier_io_s           # staged tier copies land this iter
        self._tier_io_s = 0.0
        self._iter_chunks = chunks
        self._iter_start = t
        self.busy_until = t + dur
        ep = self.epoch                  # fence: a crash invalidates this

        def fire():
            if ep == self.epoch:         # instance crashed mid-iteration?
                self._end_iteration(t + dur, admitted)
        self.events.push(t + dur, fire)

    # ---- SLO preemption (mirrors serving.Engine; DESIGN.md §SLO sched) -----
    def _victims(self, pr: int) -> List[SimRequest]:
        """Preemptable residents for a priority-``pr`` preemptor: strictly
        lower class, fully prefilled, >= 1 generated token, not mid-
        migration (the fabric owns those)."""
        return [r for r in self.running
                if not r.prefilling and not r.migrating and r.generated > 0
                and priority_of(r.req.slo_class) > pr]

    def _preempt_seat(self, head: SimRequest) -> bool:
        """Full batch: park the lowest-class largest victim — KV blocks
        stay pinned (kv_blocks counts parked), only the seat frees."""
        cands = self._victims(priority_of(head.req.slo_class))
        if not cands:
            return False
        v = max(cands, key=lambda r: (priority_of(r.req.slo_class),
                                      r.kv_len))
        self.running.remove(v)
        self._seq += 1
        # size 0: a parked request outranks an equal-deadline waiting one
        v.sched_key = queue_key(v.req.slo_class, v.req.arrival, 0.0,
                                self._seq)
        self.parked.append(v)
        v.preemptions += 1
        self.preemptions += 1
        return True

    def _preempt_mem(self, head: SimRequest, t: float) -> bool:
        """Memory-blocked admission: drop the lowest-class largest
        victim's KV and re-enqueue it as a recompute resume — running
        victims first, then parked ones (whose pinned blocks are
        otherwise unreachable)."""
        pr = priority_of(head.req.slo_class)
        cands = self._victims(pr)
        if cands:
            v = max(cands, key=lambda r: (priority_of(r.req.slo_class),
                                          r.kv_len))
            self.running.remove(v)
            self._recompute_preempt(v, t)
            return True
        pcands = [r for r in self.parked
                  if priority_of(r.req.slo_class) > pr]
        if not pcands:
            return False
        v = max(pcands, key=lambda r: (priority_of(r.req.slo_class),
                                       r.kv_len))
        self.parked.remove(v)
        self._recompute_preempt(v, t)
        return True

    def _recompute_preempt(self, v: SimRequest, t: float) -> None:
        """Drop a victim's KV; prefill must rebuild prompt + generated
        rows minus the pending last token (mirrors the engine's
        ``_requeue_recompute``)."""
        target = v.ctx_done + v.generated - 1
        v.resume_target = max(target, 1)
        v.ctx_done = 0
        v.cached_tokens = 0
        v.preemptions += 1
        v.preempted_t = t              # aging clock starts now
        self.preemptions += 1
        self.preempt_recomputes += 1
        self._seq += 1
        v.sched_key = queue_key(v.req.slo_class, v.req.arrival,
                                v.req.input_len + v.req.output_len,
                                self._seq)
        insert_sorted(self.waiting, v)

    def _age_waiting(self, t: float) -> None:
        """Starvation/aging guard (mirrors Engine._age_waiting): promote
        recompute-preempted waiters one class per TTFT budget waited."""
        changed = False
        for r in self.waiting:
            if r.preempted_t is None:
                continue
            promote = aging_promotion(r.req.slo_class, r.preempted_t, t)
            if promote <= 0:
                continue
            key = queue_key(r.req.slo_class, r.req.arrival,
                            r.req.input_len + r.req.output_len,
                            r.sched_key[3], promote=promote)
            if key != r.sched_key:
                r.sched_key = key
                changed = True
        if changed:
            ordered = sorted(self.waiting, key=lambda q: q.sched_key)
            self.waiting.clear()
            self.waiting.extend(ordered)

    def _tpot_guard(self, head: SimRequest, t: float) -> bool:
        """TPOT-deadline admission (mirrors Engine._preempt_for's guard):
        a resumed decode whose TPOT deadline is already unrecoverable
        must not preempt healthy traffic — counted once per request."""
        if head.generated <= 0 or head.first_token_t is None:
            return False
        if not tpot_hopeless(head.req.slo_class, head.first_token_t, t,
                             head.req.output_len):
            return False
        if head.req.req_id not in self._tpot_hopeless_ids:
            self._tpot_hopeless_ids.add(head.req.req_id)
            self.tpot_skipped += 1
        return True

    def _resume_ready(self) -> None:
        """Restore parked requests into free batch seats, unless a
        waiting request outranks the best parked one."""
        while self.parked and len(self.running) < self.batch_cap:
            v = min(self.parked, key=lambda r: r.sched_key)
            if self.waiting and self.waiting[0].sched_key < v.sched_key:
                return
            self.parked.remove(v)
            self.running.append(v)
            self.resumes += 1

    def _end_iteration(self, t: float, admitted: List[SimRequest]) -> None:
        # the iteration's prompt chunks land: progress advances, and a
        # request whose LAST chunk landed joins the producers this very
        # iteration (its first token — mirrors serving.Engine). A request
        # the migration fabric removed from `running` mid-iteration is
        # skipped: its shipped KV is what the receiver adopted, so the
        # source must not claim rows that never transferred. (A request
        # still resident but `migrating` DOES advance — that is live
        # migration's source-keeps-working semantics; the multi-round
        # copy model ships the delta.)
        for r, c in self._iter_chunks:
            if r in self.running:
                r.ctx_done += c
                if r.resume_target is not None:
                    if r.ctx_done >= r.resume_target:
                        # recompute resume complete: rows rebuilt, decode
                        # continues (no re-publish, no new first token)
                        r.resume_target = None
                        r.ctx_done = r.req.input_len
                        self.resumes += 1
                elif not r.prefilling:  # prompt done: prefix now servable
                    self._publish_prefix(r)
        self._iter_chunks = []
        producers = [r for r in self.running if not r.prefilling]
        n = len(producers)
        sumI = sum(r.req.input_len for r in producers)
        sumI2 = sum(r.req.input_len ** 2 for r in producers)
        sumL = sum(r.length for r in producers)
        finished: List[SimRequest] = []
        produced = 0
        for r in producers:
            if r.first_token_t is None:
                r.first_token_t = t
            r.generated += 1
            r.length += 1
            produced += 1
            r.tokens_by_instance[self.id] = \
                r.tokens_by_instance.get(self.id, 0) + 1
            # batch-load features for QoE profiling
            r.feat_sum[0] += 1.0
            r.feat_sum[1] += n
            r.feat_sum[2] += sumI
            r.feat_sum[3] += sumI2
            r.feat_sum[4] += sumL
            r.feat_iters += 1
            if r.done:
                r.finish_t = t
                finished.append(r)
        self.tokens_out += produced
        for r in finished:
            self.running.remove(r)
            if self.on_request_done:
                self.on_request_done(self, r, t)
        dur = max(t - self._iter_start, 1e-9)
        if produced:
            # EMA throughput estimate (bid-ask earliest_start payload)
            self.throughput_est = (0.8 * self.throughput_est
                                   + 0.2 * produced / dur)
        if self.on_iteration_end:
            self.on_iteration_end(self, t)
        self.iterating = False
        self.kick(t)
