"""Ground-truth hardware cost model for the simulator.

Iteration latency of a simulated instance, derived from the Pallas-kernel
block model (repro.kernels.cost) plus weight-access and per-token terms:

  decode iteration:  t_weights + n·t_tok + attn(lengths)        (memory-bound)
  prefill:           t_weights share + 2·N·I/peak + I² attention (compute-bound)

``attn(lengths)`` carries the heterogeneity tax: a padded backend pays
ceil(maxL/BS) KV blocks for *every* request. This is the physics that the
QoE model (deliberately) does not see and that CascadeInfer's scheduling
exploits — mirroring the paper's fitted-model vs. real-GPU separation.

Constants default to the assignment's TPU v5e (197 TF bf16, 819 GB/s HBM);
per-model terms come from the arch configs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.kernels.cost import (AttnSpec, HBM_BW, PEAK_FLOPS,
                                allreduce_time_s, decode_attn_time_s,
                                h2d_block_time_s, kv_bytes_per_elem,
                                mixed_iter_time_s, prefill_flops)
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    attn_spec: AttnSpec
    params: float                  # parameter count (active for MoE)
    params_total: float            # full parameter count (weight streaming)
    kv_bytes_per_token: float      # all layers, K+V
    num_layers: int
    t_fixed: float = 2e-4          # per-iteration framework overhead
    weight_bytes: float = 0.0      # bf16 weights
    peak: float = PEAK_FLOPS
    hbm: float = HBM_BW
    attn_frac: float = 1.0         # hybrid archs: fraction of layers w/ attn
    ragged_backend: bool = False   # beyond-paper kernel flag
    fused_backend: bool = False    # ONE-launch fused mixed iterations
    kv_dtype: str = "bf16"         # bf16 | int8 block pool
    # tensor parallelism (DESIGN.md §Sharded serving): chips this instance
    # spans. Per-chip terms above are already divided by it; the iteration
    # models add the ring-all-reduce collectives it costs (needs d_model).
    num_devices: int = 1
    d_model: int = 0

    @property
    def t_weights(self) -> float:
        """Weight-streaming floor of one decode iteration (memory-bound)."""
        return self.weight_bytes / self.hbm

    def t_collective(self, n_tokens: float) -> float:
        """Per-iteration tensor-parallel collective time: two psums per
        layer (attention wo + FFN down projections) of an
        [n_tokens, d_model] bf16 activation over the instance's chips.
        Zero at num_devices == 1 — untouched single-chip parity."""
        payload = 2.0 * self.num_layers * float(n_tokens) * self.d_model * 2.0
        return allreduce_time_s(payload, self.num_devices)


def profile_from_config(cfg: ModelConfig, *, tp: int = 1,
                        ragged_backend: bool = False,
                        fused_backend: bool = False,
                        kv_dtype: str = "bf16") -> HardwareProfile:
    """Build a per-instance hardware profile from a model config.
    ``tp``: tensor-parallel ways (DESIGN.md §Sharded serving) — divides
    weights + KV per chip AND the attention grid's head counts (each
    shard owns H/tp q heads over Hkv/tp kv heads, so the GQA ratio and
    per-block time are unchanged while the per-chip grid shrinks tp×);
    the iteration models then add the 2-psum/layer collective term.
    ``kv_dtype="int8"`` prices the quantized block pool — per-token KV
    bytes (and so block bytes / capacity) shrink by ``(Dh+4)/(2·Dh)``,
    and every attention DMA term moves the smaller bytes."""
    d, L = cfg.d_model, cfg.num_layers
    if cfg.num_experts:
        ffn_p = 3 * d * cfg.d_ff
        dense_p = ffn_p * (cfg.num_experts + (1 if cfg.dense_residual else 0))
        active_p = ffn_p * (cfg.experts_per_token
                            + (1 if cfg.dense_residual else 0))
    else:
        mult = 3 if cfg.act == "swiglu" else 2
        dense_p = active_p = mult * d * cfg.d_ff
    if cfg.num_heads:
        attn_p = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
            + cfg.num_heads * cfg.head_dim * d
        kv_elem = kv_bytes_per_elem(kv_dtype, cfg.head_dim)
        spec = AttnSpec(max(cfg.num_heads // tp, 1),
                        max(cfg.num_kv_heads // tp, 1), cfg.head_dim,
                        kv_bytes=kv_elem)
        kv_tok = 2 * cfg.num_kv_heads * cfg.head_dim * kv_elem  # K+V
        attn_layers = (L // cfg.attn_every) if cfg.attn_every else L
    else:  # attention-free (rwkv): state is O(1); no per-token KV
        attn_p = 4 * d * d
        spec = AttnSpec(1, 1, 128)
        kv_tok = 0.0
        attn_layers = 0
    embed_p = 2 * cfg.vocab_size * d
    n_total = L * (attn_p + dense_p) + embed_p
    n_active = L * (attn_p + active_p) + embed_p
    return HardwareProfile(
        attn_spec=spec,
        params=n_active / tp,
        params_total=n_total / tp,
        kv_bytes_per_token=kv_tok * attn_layers / tp,
        num_layers=L,
        weight_bytes=2.0 * n_total / tp,
        attn_frac=attn_layers / max(L, 1),
        ragged_backend=ragged_backend,
        fused_backend=fused_backend,
        kv_dtype=kv_dtype,
        num_devices=tp,
        d_model=d,
    )


def scale_profile_tp(prof: HardwareProfile, tp: int) -> HardwareProfile:
    """Re-shard a single-chip profile across ``tp`` chips (DESIGN.md
    §Sharded serving): per-chip weights/KV shrink tp×, the attention grid
    keeps H/tp q heads over Hkv/tp kv heads (GQA ratio unchanged), and
    ``num_devices`` turns on the collective term. ``tp <= 1`` returns the
    profile unchanged, so homogeneous clusters are bit-identical."""
    if tp <= 1:
        return prof
    spec = prof.attn_spec
    return dataclasses.replace(
        prof,
        attn_spec=dataclasses.replace(
            spec,
            num_q_heads=max(spec.num_q_heads // tp, 1),
            num_kv_heads=max(spec.num_kv_heads // tp, 1)),
        params=prof.params / tp,
        params_total=prof.params_total / tp,
        kv_bytes_per_token=prof.kv_bytes_per_token / tp,
        weight_bytes=prof.weight_bytes / tp,
        num_devices=tp,
    )


def decode_iter_time(lengths: Sequence[int], prof: HardwareProfile) -> float:
    """One continuous-batching decode iteration over ``lengths``."""
    n = len(lengths)
    if n == 0:
        return 0.0
    t_tok = 2.0 * prof.params / prof.peak                 # per-request MXU
    attn_layers = round(prof.num_layers * prof.attn_frac)
    t_attn = (decode_attn_time_s(lengths, prof.attn_spec,
                                 ragged=prof.ragged_backend) * attn_layers
              if attn_layers else 0.0)
    return (prof.t_fixed + prof.t_weights + n * t_tok + t_attn
            + prof.t_collective(n))


def prefill_time(input_len: int, prof: HardwareProfile,
                 cached_tokens: int = 0) -> float:
    """Monolithic prefill iteration for one whole prompt (compute-bound).
    The quadratic attention term comes from the kernel-level chunk mirror
    (``kernels.cost.prefill_chunk_flops`` with the prompt as one chunk ≈
    the old 2·H·Dh·I² causal count) — one formula prices every prefill
    granularity. ``cached_tokens`` prompt tokens served from the prefix
    cache (DESIGN.md §Prefix cache) never run: linear work covers only
    the uncached tail and the attention term is the tail-against-cached-
    context chunk count."""
    cached = min(int(cached_tokens), max(int(input_len) - 1, 0))
    I = float(input_len) - cached
    t_linear = 2.0 * prof.params * I / prof.peak
    attn_layers = round(prof.num_layers * prof.attn_frac)
    t_quad = (prefill_flops(int(input_len), prof.attn_spec, cached)
              * attn_layers / prof.peak)
    return prof.t_fixed + t_linear + t_quad + prof.t_collective(I)


def mixed_iter_time(chunks: Sequence, decode_lengths: Sequence[int],
                    prof: HardwareProfile) -> float:
    """One token-budgeted MIXED iteration (DESIGN.md §Chunked prefill):
    the full decode batch over ``decode_lengths`` advances one token while
    ``chunks`` — (chunk_len, ctx_len) prompt slices — prefill beside it.
    Linear (weight) work scales with decode batch + chunk tokens; the
    attention terms are the kernel mirrors (paged chunked prefill + the
    SAME decode backend ``decode_iter_time`` prices, per
    ``prof.ragged_backend`` — so chunked-vs-monolithic runs differ only
    in prefill scheduling, never in the decode kernel model). This
    replaces the dedicated-prefill-iteration model wherever the instance
    runs the chunked scheduler."""
    n = len(decode_lengths)
    if n == 0 and not chunks:
        return 0.0
    t_tok = 2.0 * prof.params / prof.peak                 # per-request MXU
    chunk_toks = sum(int(c) for c, _ in chunks)
    t_linear = 2.0 * prof.params * chunk_toks / prof.peak
    attn_layers = round(prof.num_layers * prof.attn_frac)
    backend = ("fused" if prof.fused_backend
               else "ragged" if prof.ragged_backend else "padded")
    t_attn = (mixed_iter_time_s(chunks, decode_lengths, prof.attn_spec,
                                decode_backend=backend)
              * attn_layers if attn_layers else 0.0)
    return (prof.t_fixed + prof.t_weights + n * t_tok + t_linear + t_attn
            + prof.t_collective(n + chunk_toks))


def kv_block_bytes(prof: HardwareProfile, block_size: int) -> float:
    """Bytes of one paged-cache block (all layers, K+V) — the allocation
    unit the serving engine's BlockAllocator hands out; capacity planning
    and migration volume accounting are multiples of this."""
    return prof.kv_bytes_per_token * block_size


def promote_time(n_blocks: int, prof: HardwareProfile,
                 block_size: int) -> float:
    """Host→device staging time for ``n_blocks`` promoted KV blocks
    (DESIGN.md §Multi-tier KV): per-block launch overhead + bytes over
    the host staging link — the same ``kernels.cost.h2d_block_time_s``
    the engine's promote pricing uses, applied to this profile's block
    bytes. This is what a host-tier prefix hit costs the admission
    iteration (the truly-uncached tail still prefills on top)."""
    if n_blocks <= 0:
        return 0.0
    return n_blocks * h2d_block_time_s(kv_block_bytes(prof, block_size))


def demote_time(n_blocks: int, prof: HardwareProfile,
                block_size: int) -> float:
    """Device→host flush time for ``n_blocks`` demoted KV blocks. The
    engine stages demotes asynchronously (the device-side snapshot
    overlaps the running iteration) but the host-side flush still
    occupies the step's wall clock — priced symmetrically to
    :func:`promote_time` over the same staging link."""
    if n_blocks <= 0:
        return 0.0
    return n_blocks * h2d_block_time_s(kv_block_bytes(prof, block_size))


def capacity_blocks(hbm_bytes_free: float, prof: HardwareProfile,
                    block_size: int) -> int:
    """How many KV blocks fit in the HBM left after weights — the paged
    engine's ``num_blocks`` for a given chip."""
    bb = kv_block_bytes(prof, block_size)
    return int(hbm_bytes_free // max(bb, 1e-9))


def decode_rate(lengths: Sequence[int], prof: HardwareProfile) -> float:
    """Tokens/s one request sees inside the current batch (for live-
    migration round planning)."""
    t = decode_iter_time(lengths, prof)
    return 1.0 / max(t, 1e-9)
