"""MILS cluster simulator: policies (round-robin / Llumnix-like /
CascadeInfer) over simulated instances with live KV migration.

CascadePolicy composes the paper's mechanisms end to end: offline pipeline
plan -> length routing -> growth-triggered inter-stage handover with
bid-ask receiver selection -> intra-stage bid-ask rebalancing -> periodic
adaptive range refinement -> live migration with concurrency caps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bidask import (Bid, MigRequest, ReceiverState, SenderState,
                               is_overloaded, select_receiver)
from repro.core.migration import plan_live_migration
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.core.refinement import (BoundaryRefiner, memory_based_split,
                                   quantity_based_split)
from repro.sim.costmodel import HardwareProfile, decode_rate
from repro.sim.events import EventQueue
from repro.sim.instance import Instance, SimRequest
from repro.sim.workload import Request


@dataclasses.dataclass
class ClusterConfig:
    num_instances: int = 16
    capacity_tokens: float = 400_000.0
    kv_block_size: int = 16            # paged-cache allocation granularity
    bandwidth: float = 25e9            # inter-instance KV path
    # hand-off disruption: final stop-and-copy stall + scheduler/alloc
    # coordination on both ends (Llumnix reports tens of ms per migration);
    # the request decodes nowhere during this window.
    migration_pause_s: float = 0.05
    refine_interval: float = 10.0
    balance_interval: float = 2.0
    pump_interval: float = 0.5
    drain_factor: float = 20.0         # max extra sim time to drain
    seed: int = 0


class Policy:
    name = "base"

    def attach(self, cluster: "Cluster") -> None:
        self.cluster = cluster

    def route(self, sr: SimRequest, t: float) -> Instance:
        raise NotImplementedError

    def on_iteration_end(self, inst: Instance, t: float) -> None:
        pass

    def timers(self) -> List[Tuple[float, Callable[[float], None]]]:
        return []


class Cluster:
    def __init__(self, profile: HardwareProfile, policy: Policy,
                 cfg: ClusterConfig):
        self.cfg = cfg
        self.profile = profile
        self.events = EventQueue()
        self.rng = np.random.default_rng(cfg.seed)
        self.instances = [
            Instance(i, profile, cfg.capacity_tokens, self.events,
                     block_size=cfg.kv_block_size)
            for i in range(cfg.num_instances)]
        self.completed: List[SimRequest] = []
        self.policy = policy
        policy.attach(self)
        for inst in self.instances:
            inst.on_iteration_end = policy.on_iteration_end
            inst.on_request_done = self._on_done

    def _on_done(self, inst: Instance, sr: SimRequest, t: float) -> None:
        self.completed.append(sr)

    def submit(self, req: Request) -> None:
        def arrive():
            sr = SimRequest(req=req, length=req.input_len)
            inst = self.policy.route(sr, self.events.now)
            inst.enqueue(sr, self.events.now)
        self.events.push(req.arrival, arrive)

    def run(self, requests: Sequence[Request], duration: float) -> "SimResult":
        for r in requests:
            self.submit(r)
        for interval, fn in self.policy.timers():
            self._periodic(interval, fn)
        self.events.run_until(duration)
        # drain: keep going until every submitted request completes
        t_max = duration * self.cfg.drain_factor
        while (len(self.completed) < len(requests)
               and self.events.now < t_max and len(self.events)):
            self.events.run_until(min(self.events.now + duration, t_max))
        from repro.sim.metrics import SimResult
        return SimResult(completed=list(self.completed),
                         duration=self.events.now,
                         num_submitted=len(requests),
                         instances=self.instances,
                         policy_name=self.policy.name,
                         stage_of_instance=getattr(
                             self.policy, "stage_of_instance", None))

    def _periodic(self, interval: float, fn: Callable[[float], None]) -> None:
        def tick():
            fn(self.events.now)
            self.events.push(self.events.now + interval, tick)
        self.events.push(interval, tick)


# --------------------------------------------------------------------------
# Baseline policies
# --------------------------------------------------------------------------
class RoundRobinPolicy(Policy):
    """vLLM/SGLang deployment baseline (§6.1): stateless round-robin LB."""
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, sr, t):
        inst = self.cluster.instances[self._next % len(self.cluster.instances)]
        self._next += 1
        return inst


class LlumnixLikePolicy(Policy):
    """Length-agnostic load/memory-aware inter-instance scheduling with
    live migration on overload (Llumnix's core heuristics, §2.4)."""
    name = "llumnix-like"

    def __init__(self, migration: bool = True):
        self.migration = migration

    def attach(self, cluster):
        super().attach(cluster)
        self._mover = TransferFabric(cluster)

    def route(self, sr, t):
        # least total load (KV + queued work) — Llumnix routes on load and
        # free memory; queue-blind routing herds onto backlogged instances
        return min(self.cluster.instances, key=lambda i: i.load())

    def timers(self):
        return [(self.cluster.cfg.balance_interval, self._balance)]

    def _balance(self, t):
        if not self.migration:
            return
        insts = self.cluster.instances
        loads = [i.load() for i in insts]
        for inst in insts:
            peers = [l for j, l in enumerate(loads) if j != inst.id]
            if not is_overloaded(inst.load(), peers):
                continue
            target = max(insts, key=lambda i: i.free_tokens())
            if target.id == inst.id:
                continue
            cands = [r for r in inst.running if not r.migrating]
            if not cands:
                continue
            victim = max(cands, key=lambda r: r.length)   # memory-aware
            self._mover.direct_transfer(inst, target, victim, t)


# --------------------------------------------------------------------------
# Transfer fabric: live migration with concurrency + flow control
# --------------------------------------------------------------------------
class TransferFabric:
    """Shared KV-migration machinery (used by Llumnix-like and Cascade)."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster

    def direct_transfer(self, src: Instance, dst: Instance,
                        sr: SimRequest, t: float) -> bool:
        if sr.migrating or sr.done:
            return False
        # flow control + wire volume are block-granular: the receiver must
        # have whole free blocks, and we move whole blocks (gather→scatter)
        need = dst.block_tokens(sr.length)
        if not src.migrations.can_start(dst.free_tokens() >= need):
            return False
        sr.migrating = True
        dst.inbound_reserved += need
        rate = decode_rate([r.length for r in src.running], src.profile)
        timing = plan_live_migration(need, rate,
                                     src.profile.kv_bytes_per_token or 2e5,
                                     self.cluster.cfg.bandwidth)
        src.migrations.start(sr.req.req_id, t + timing.total_s)

        pause = self.cluster.cfg.migration_pause_s + timing.stall_s

        def finish():
            now = self.cluster.events.now
            src.migrations.finish(sr.req.req_id)
            if sr.done or sr not in src.running:
                dst.inbound_reserved -= need
                sr.migrating = False
                return        # completed mid-flight: drop the move
            src.running.remove(sr)
            src.kick(now)

            def adopt():     # stop-and-copy + scheduler hand-off pause
                dst.inbound_reserved -= need
                sr.migrating = False
                dst.adopt_running(sr, self.cluster.events.now)

            self.cluster.events.push(now + pause, adopt)

        self.cluster.events.push(t + timing.total_s, finish)
        return True


# --------------------------------------------------------------------------
# CascadeInfer
# --------------------------------------------------------------------------
@dataclasses.dataclass
class StageState:
    lo: float
    hi: float
    instance_ids: List[int]


class CascadePolicy(Policy):
    """The paper's system. Ablation knobs:
      refinement ∈ {adaptive, quantity, memory, none}   (Fig. 15)
      balancing  ∈ {full, inter-stage, rr}              (Fig. 16)
      plan layout chain/no-pipeline comes from the plan (Fig. 14)
    """
    name = "cascade"

    def __init__(self, plan: PipelinePlan, qoe: QoEModel, *,
                 refinement: str = "adaptive", balancing: str = "full",
                 kv_bytes_per_token: Optional[float] = None):
        self.plan = plan
        self.qoe = qoe
        self.refinement = refinement
        self.balancing = balancing
        self.kv_bytes_per_token = kv_bytes_per_token
        self._rr_counters: Dict[int, int] = {}

    def attach(self, cluster):
        super().attach(cluster)
        self.fabric = TransferFabric(cluster)
        self.senders = {i.id: SenderState(i.id) for i in cluster.instances}
        self.receivers = {i.id: ReceiverState(i.id) for i in cluster.instances}
        self._pending: Dict[int, Tuple[SimRequest, int]] = {}  # req -> (sr, src)
        # assign instances to stages
        self.stages: List[StageState] = []
        self.stage_of_instance: List[int] = [0] * len(cluster.instances)
        nxt = 0
        for si, st in enumerate(self.plan.stages):
            ids = list(range(nxt, nxt + st.num_instances))
            nxt += st.num_instances
            self.stages.append(StageState(st.lo, st.hi, ids))
            for i in ids:
                self.stage_of_instance[i] = si
        assert nxt == len(cluster.instances), \
            f"plan uses {nxt} instances, cluster has {len(cluster.instances)}"
        self.refiners = [
            BoundaryRefiner(self.qoe, boundary=s.hi)
            for s in self.stages[:-1]]

    # ---- routing -----------------------------------------------------------
    def _stage_for(self, length: float) -> int:
        for i, s in enumerate(self.stages):
            if length < s.hi:
                return i
        return len(self.stages) - 1

    def route(self, sr, t):
        """Arrivals go round-robin within the covering stage (§3.2 —
        bid-ask governs *migrations*, not dispatch)."""
        si = self._stage_for(sr.length)
        ids = self.stages[si].instance_ids
        c = self._rr_counters.get(si, 0)
        self._rr_counters[si] = c + 1
        return self.cluster.instances[ids[c % len(ids)]]

    # ---- growth-triggered handover (inter-stage) ----------------------------
    def on_iteration_end(self, inst, t):
        si = self.stage_of_instance[inst.id]
        hi = self.stages[si].hi
        if hi == float("inf"):
            return
        for sr in list(inst.running):
            if sr.length >= hi and not sr.migrating \
                    and sr.req.req_id not in self._pending:
                nxt = min(si + 1, len(self.stages) - 1)
                self._offer(inst, sr, self.stages[nxt].instance_ids, t)

    def _offer(self, src: Instance, sr: SimRequest,
               candidate_ids: Sequence[int], t: float) -> None:
        sender = self.senders[src.id]
        mig = MigRequest(sr.req.req_id, sr.length, src.id)
        sender.offer(mig)
        self._pending[sr.req.req_id] = (sr, src.id)
        cands = [self.cluster.instances[i] for i in candidate_ids
                 if i != src.id]
        if self.balancing == "rr":
            # Fig.-16 ablation: hand over round-robin, no negotiation
            c = self._rr_counters.get(-1, 0)
            self._rr_counters[-1] = c + 1
            rid = cands[c % len(cands)].id if cands else None
        else:
            bids = [Bid(c.id, c.load(),
                        self.receivers[c.id].earliest_start(),
                        int(self.cluster.rng.integers(0, 1 << 30)))
                    for c in cands]
            rid = select_receiver(bids)
        if rid is None:
            sender.buffer.pop(mig.req_id, None)
            self._pending.pop(sr.req.req_id, None)
            return
        self.receivers[rid].win(mig)
        self._pump(rid, t)

    # ---- receiver pull loop -------------------------------------------------
    def _sender_busy(self, src_id: int) -> bool:
        return self.senders[src_id].transmitting is not None

    def _pump(self, rid: int, t: float) -> None:
        recv = self.receivers[rid]
        dst = self.cluster.instances[rid]
        while True:
            mig, starved = recv.next_pull(self._sender_busy)
            if starved is not None:
                self.senders[
                    self._pending[starved][1]].mark_starved(starved)
            if mig is None:
                return
            if not self._begin_transfer(mig, dst, t):
                recv.win(mig)          # put back; retry on next pump
                return

    def _begin_transfer(self, mig: MigRequest, dst: Instance,
                        t: float) -> bool:
        entry = self._pending.get(mig.req_id)
        if entry is None:
            return True                # stale (request finished)
        sr, src_id = entry
        src = self.cluster.instances[src_id]
        sender = self.senders[src_id]
        if sr.done or sr not in src.running:
            sender.buffer.pop(mig.req_id, None)
            self._pending.pop(mig.req_id, None)
            return True
        if not sender.can_transmit(mig.req_id):
            return False
        need = dst.block_tokens(sr.length)
        if not src.migrations.can_start(dst.free_tokens() >= need):
            return False               # §5 flow control: stay on source
        sender.begin(mig.req_id)
        sr.migrating = True
        dst.inbound_reserved += need
        rate = decode_rate([r.length for r in src.running], src.profile)
        kvb = self.kv_bytes_per_token or src.profile.kv_bytes_per_token or 2e5
        timing = plan_live_migration(need, rate, kvb,
                                     self.cluster.cfg.bandwidth)
        src.migrations.start(mig.req_id, t + timing.total_s)

        pause = self.cluster.cfg.migration_pause_s + timing.stall_s

        def finish():
            now = self.cluster.events.now
            src.migrations.finish(mig.req_id)
            sender.finish(mig.req_id)
            self.receivers[dst.id].complete(mig.req_id)
            self._pending.pop(mig.req_id, None)
            if sr.done or sr not in src.running:
                dst.inbound_reserved -= need
                sr.migrating = False
                self._pump(dst.id, now)
                return
            src.running.remove(sr)
            src.kick(now)

            def adopt():     # stop-and-copy + scheduler hand-off pause
                dst.inbound_reserved -= need
                sr.migrating = False
                dst.adopt_running(sr, self.cluster.events.now)

            self.cluster.events.push(now + pause, adopt)
            self._pump(dst.id, now)

        self.cluster.events.push(t + timing.total_s, finish)
        return True

    # ---- timers: pump / intra-stage balance / refinement ---------------------
    def timers(self):
        out = [(self.cluster.cfg.pump_interval, self._pump_all)]
        if self.balancing == "full":
            out.append((self.cluster.cfg.balance_interval, self._balance))
        if self.refinement != "none":
            out.append((self.cluster.cfg.refine_interval, self._refine))
        return out

    def _pump_all(self, t):
        for rid in self.receivers:
            if len(self.receivers[rid]):
                self._pump(rid, t)

    def _balance(self, t):
        for si, stage in enumerate(self.stages):
            insts = [self.cluster.instances[i] for i in stage.instance_ids]
            if len(insts) < 2:
                continue
            loads = {i.id: i.load() for i in insts}
            for inst in insts:
                peers = [l for j, l in loads.items() if j != inst.id]
                if not is_overloaded(inst.load(), peers):
                    continue
                cands = [r for r in inst.running
                         if not r.migrating
                         and r.req.req_id not in self._pending]
                if not cands:
                    continue
                victim = max(cands, key=lambda r: r.length)
                self._offer(inst, victim,
                            [i.id for i in insts if i.id != inst.id], t)

    def _refine(self, t):
        for bi in range(len(self.stages) - 1):
            own_ids = self.stages[bi].instance_ids
            succ_ids = self.stages[bi + 1].instance_ids
            own = [rv for i in own_ids
                   for rv in self.cluster.instances[i].request_view()]
            succ = [self.cluster.instances[i].request_view()
                    for i in succ_ids]
            if self.refinement == "adaptive":
                b = self.refiners[bi].refine(own, succ)
            else:
                merged = own + [r for s in succ for r in s]
                if len(merged) < self.refiners[bi].min_requests:
                    continue
                if self.refinement == "quantity":
                    b = quantity_based_split(merged)
                elif self.refinement == "memory":
                    b = memory_based_split(merged)
                else:
                    continue
                self.refiners[bi].boundary = b
            # keep boundaries monotone across stages
            lo = self.stages[bi].lo
            hi_next = self.stages[bi + 1].hi
            b = float(np.clip(b, lo + 1.0,
                              hi_next - 1.0 if hi_next != float("inf")
                              else b))
            self.stages[bi].hi = b
            self.stages[bi + 1].lo = b
