"""MILS cluster simulator: policies (round-robin / Llumnix-like /
CascadeInfer) over simulated instances with live KV migration.

CascadePolicy is a thin *driver* of the backend-agnostic scheduling core
(`repro.control.plane.ControlPlane`): it supplies discrete-event timing,
the cost-model transfer fabric, and `InstanceView`/`ClusterOps` adapters
over simulated instances — every routing/handover/balance/refinement
decision is made by the shared core, the same code the real multi-engine
server (`repro.serving.server.MILSServer`) runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.control import (MIG_STARTED, XFER_LOST, XFER_OK, XFER_STALL,
                           ControlConfig, ControlPlane, FaultInjector,
                           FaultSpec, ReqView, is_overloaded)
from repro.core.migration import plan_live_migration
from repro.core.partition import PipelinePlan
from repro.core.qoe import QoEModel
from repro.kernels.cost import promote_cost_tokens
from repro.sim.costmodel import (HardwareProfile, decode_rate,
                                 scale_profile_tp)
from repro.sim.events import EventQueue
from repro.sim.instance import Instance, SimRequest
from repro.sim.workload import Request


@dataclasses.dataclass
class ClusterConfig:
    num_instances: int = 16
    capacity_tokens: float = 400_000.0
    # per-instance tensor-parallel ways (DESIGN.md §Sharded serving):
    # None = homogeneous single-chip cluster (bit-identical legacy). A
    # tuple of num_instances entries gives instance i a tp=tps[i] engine:
    # its profile re-shards via scale_profile_tp and its KV capacity is
    # capacity_tokens × tps[i] (capacity_tokens stays PER-DEVICE, exactly
    # like Engine.token_budget).
    tps: Optional[Tuple[int, ...]] = None
    kv_block_size: int = 16            # paged-cache allocation granularity
    # prompt-chunk tokens per mixed iteration (DESIGN.md §Chunked
    # prefill), mirroring serving.Engine's token-budgeted scheduler;
    # None = legacy monolithic prefill-at-admission (the §2.1 baseline)
    prefill_token_budget: Optional[int] = None
    # group-granular prefix-cache mirror (DESIGN.md §Prefix cache);
    # active only for chunked instances on workloads carrying prefix
    # groups, so legacy runs are bit-identical either way
    prefix_cache: bool = True
    # SLO-tiered preemptive scheduling (DESIGN.md §SLO scheduling):
    # deadline-ordered queues + seat/memory preemption of lower classes.
    # Uniform-class traffic with distinct arrivals is FCFS either way.
    preemption: bool = True
    # multi-tier KV (DESIGN.md §Multi-tier KV): host-RAM tier capacity in
    # tokens per instance. 0 = tiering off — idle published prefixes cost
    # nothing and are never demoted (the legacy no-reclaim model,
    # bit-identical).
    host_kv_budget: int = 0
    bandwidth: float = 25e9            # inter-instance KV path
    # hand-off disruption: final stop-and-copy stall + scheduler/alloc
    # coordination on both ends (Llumnix reports tens of ms per migration);
    # the request decodes nowhere during this window.
    migration_pause_s: float = 0.05
    refine_interval: float = 10.0
    balance_interval: float = 2.0
    pump_interval: float = 0.5
    drain_factor: float = 20.0         # max extra sim time to drain
    seed: int = 0
    # ---- fault tolerance (DESIGN.md §Fault tolerance) ----
    # None = fault-free run, bit-identical to the pre-fault simulator (no
    # heartbeat/timeout events exist to perturb event-queue tie ordering)
    faults: Optional[FaultSpec] = None
    # wire deadline for one migration; None = auto (4x the planned copy
    # time + 1s). Deadline events exist only in faulty runs.
    migration_timeout_s: Optional[float] = None
    heartbeat_interval: float = 0.5
    suspect_after_s: float = 3.0
    dead_after_s: float = 6.0
    redispatch_budget: int = 2


class Policy:
    name = "base"

    def attach(self, cluster: "Cluster") -> None:
        self.cluster = cluster

    def route(self, sr: SimRequest, t: float) -> Instance:
        raise NotImplementedError

    def dispatch(self, sr: SimRequest, t: float) -> None:
        self.route(sr, t).enqueue(sr, t)

    def on_iteration_end(self, inst: Instance, t: float) -> None:
        pass

    def timers(self) -> List[Tuple[float, Callable[[float], None]]]:
        return []


class Cluster:
    def __init__(self, profile: HardwareProfile, policy: Policy,
                 cfg: ClusterConfig):
        self.cfg = cfg
        self.profile = profile
        self.events = EventQueue()
        self.rng = np.random.default_rng(cfg.seed)
        tps = cfg.tps
        if tps is not None:
            assert len(tps) == cfg.num_instances, \
                f"tps has {len(tps)} entries for {cfg.num_instances} instances"
        self.instances = [
            Instance(i,
                     scale_profile_tp(profile, tps[i]) if tps else profile,
                     cfg.capacity_tokens * (tps[i] if tps else 1),
                     self.events,
                     block_size=cfg.kv_block_size,
                     prefill_budget=cfg.prefill_token_budget,
                     prefix_cache=cfg.prefix_cache,
                     preemption=cfg.preemption,
                     host_kv_blocks=cfg.host_kv_budget
                     // cfg.kv_block_size)
            for i in range(cfg.num_instances)]
        self.completed: List[SimRequest] = []
        self.injector = (FaultInjector(cfg.faults)
                         if cfg.faults is not None else None)
        if self.injector is not None:
            for inst in self.instances:
                inst.slowdown = self.injector.slowdown(inst.id)
        self.policy = policy
        policy.attach(self)
        for inst in self.instances:
            inst.on_iteration_end = policy.on_iteration_end
            inst.on_request_done = self._on_done

    def _on_done(self, inst: Instance, sr: SimRequest, t: float) -> None:
        self.completed.append(sr)

    def submit(self, req: Request) -> None:
        def arrive():
            sr = SimRequest(req=req, length=req.input_len)
            self.policy.dispatch(sr, self.events.now)
        self.events.push(req.arrival, arrive)

    def _revive(self, inst: Instance) -> None:
        inst.clear_crashed()           # idempotent: rejoin starts empty
        inst.revive(self.events.now)
        inst.kick(self.events.now)

    def run(self, requests: Sequence[Request], duration: float) -> "SimResult":
        for r in requests:
            self.submit(r)
        if self.cfg.faults is not None:
            # scripted chaos: crashes/rejoins are ordinary events.
            # all_crashes expands correlated rack events into the same
            # per-instance schedule, so several instances can die in one
            # tick (deterministic same-tick order: listed order).
            for iid, at in self.cfg.faults.all_crashes:
                self.events.push(
                    at, lambda i=self.instances[iid]: i.crash(self.events.now))
            for iid, at in self.cfg.faults.rejoins:
                self.events.push(
                    at, lambda i=self.instances[iid]: self._revive(i))
        for interval, fn in self.policy.timers():
            self._periodic(interval, fn)
        self.events.run_until(duration)
        # drain: keep going until every submitted request completes (a
        # failed request counts as completed — it must not hang the run)
        t_max = duration * self.cfg.drain_factor
        while (len(self.completed) < len(requests)
               and self.events.now < t_max and len(self.events)):
            self.events.run_until(min(self.events.now + duration, t_max))
        from repro.sim.metrics import SimResult
        plane = getattr(self.policy, "plane", None)
        return SimResult(completed=list(self.completed),
                         duration=self.events.now,
                         num_submitted=len(requests),
                         instances=self.instances,
                         policy_name=self.policy.name,
                         stage_of_instance=getattr(
                             self.policy, "stage_of_instance", None),
                         retries=plane.retries if plane is not None else 0)

    def _periodic(self, interval: float, fn: Callable[[float], None]) -> None:
        def tick():
            fn(self.events.now)
            self.events.push(self.events.now + interval, tick)
        self.events.push(interval, tick)


# --------------------------------------------------------------------------
# Baseline policies
# --------------------------------------------------------------------------
class RoundRobinPolicy(Policy):
    """vLLM/SGLang deployment baseline (§6.1): stateless round-robin LB."""
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, sr, t):
        inst = self.cluster.instances[self._next % len(self.cluster.instances)]
        self._next += 1
        return inst


class LlumnixLikePolicy(Policy):
    """Length-agnostic load/memory-aware inter-instance scheduling with
    live migration on overload (Llumnix's core heuristics, §2.4)."""
    name = "llumnix-like"

    def __init__(self, migration: bool = True):
        self.migration = migration

    def attach(self, cluster):
        super().attach(cluster)
        self._mover = TransferFabric(cluster)

    def route(self, sr, t):
        # least total load (KV + queued work) — Llumnix routes on load and
        # free memory; queue-blind routing herds onto backlogged instances
        return min(self.cluster.instances, key=lambda i: i.load())

    def timers(self):
        return [(self.cluster.cfg.balance_interval, self._balance)]

    def _balance(self, t):
        if not self.migration:
            return
        insts = self.cluster.instances
        loads = [i.load() for i in insts]
        for inst in insts:
            peers = [l for j, l in enumerate(loads) if j != inst.id]
            if not is_overloaded(inst.load(), peers):
                continue
            target = max(insts, key=lambda i: i.free_tokens())
            if target.id == inst.id:
                continue
            cands = [r for r in inst.running if not r.migrating]
            if not cands:
                continue
            victim = max(cands, key=lambda r: r.length)   # memory-aware
            self._mover.direct_transfer(inst, target, victim, t)


# --------------------------------------------------------------------------
# Transfer fabric: live migration with concurrency + flow control
# --------------------------------------------------------------------------
class TransferFabric:
    """Shared KV-migration machinery (used by Llumnix-like and Cascade)."""

    def __init__(self, cluster: Cluster,
                 kv_bytes_per_token: Optional[float] = None):
        self.cluster = cluster
        self.kv_bytes_per_token = kv_bytes_per_token
        # fault wiring (set by CascadePolicy.attach on faulty runs):
        # injector decides per-attempt wire fates; on_failed(req_id)
        # reports a transfer that will never land (-> plane rollback)
        self.injector: Optional[FaultInjector] = None
        self.on_failed: Optional[Callable[[int], None]] = None

    def direct_transfer(self, src: Instance, dst: Instance,
                        sr: SimRequest, t: float) -> bool:
        """Llumnix path: gate on the receiver's room + source cap, then
        move. (Cascade gates in the control plane and calls ``transfer``.)"""
        if sr.migrating or sr.done:
            return False
        need = dst.block_tokens(sr.length)
        if not src.migrations.can_start(dst.free_tokens() >= need):
            return False
        self.transfer(src, dst, sr, t)
        return True

    def transfer(self, src: Instance, dst: Instance, sr: SimRequest,
                 t: float, on_finish: Optional[Callable] = None) -> None:
        """Start a live migration: multi-round copy timing from the cost
        model, block-granular reservation on the receiver, stop-and-copy
        pause, then adoption. ``on_finish(arrived)`` fires when the
        transfer leaves the wire (before the adoption pause). A
        half-prefilled request ships only its ``ctx_done`` written blocks
        (the receiver resumes chunking — DESIGN.md §Chunked prefill), but
        the receiver-side reservation covers the FULL current length:
        the un-prefilled remainder materializes right after adoption, and
        gating on the written part alone would let the receiver overflow
        (the real engine reserves the worst case at import)."""
        need = dst.block_tokens(sr.length)          # eventual footprint
        ship = dst.block_tokens(sr.kv_len)          # crosses the wire now
        sr.migrating = True
        dst.inbound_reserved += need
        rate = decode_rate([r.length for r in src.running], src.profile)
        kvb = (self.kv_bytes_per_token or src.profile.kv_bytes_per_token
               or 2e5)
        timing = plan_live_migration(ship, rate, kvb,
                                     self.cluster.cfg.bandwidth)
        src.migrations.start(sr.req.req_id, t + timing.total_s)

        pause = self.cluster.cfg.migration_pause_s + timing.stall_s
        # fault machinery (DESIGN.md §Fault tolerance): epoch fences a
        # receiver crash (its reservations were wiped with the carcass),
        # `state` makes delivery and the wire deadline mutually exclusive
        dst_ep = dst.epoch
        state = {"settled": False}

        def release():
            if dst.alive and dst.epoch == dst_ep:
                dst.inbound_reserved -= need

        def finish():
            if state["settled"]:
                return                 # the deadline already rolled back
            state["settled"] = True
            now = self.cluster.events.now
            src.migrations.finish(sr.req.req_id)
            if sr.done or sr not in src.running:
                release()
                sr.migrating = False
                if on_finish:
                    on_finish(False)   # completed mid-flight: drop the move
                return
            if not dst.alive or dst.epoch != dst_ep:
                # receiver died with the payload on the wire: ownership
                # never flipped, the request survives on its source
                sr.migrating = False
                if self.on_failed:
                    self.on_failed(sr.req.req_id)
                return
            src.running.remove(sr)
            src.kick(now)

            def adopt():     # stop-and-copy + scheduler hand-off pause
                now2 = self.cluster.events.now
                if not dst.alive or dst.epoch != dst_ep:
                    # receiver died inside the hand-off pause: bounce the
                    # request back to its source (KV still lives there —
                    # ownership flips only at adoption)
                    sr.migrating = False
                    if self.on_failed:
                        self.on_failed(sr.req.req_id)
                    if src.alive and not sr.done:
                        src.adopt_running(sr, now2)
                    else:
                        # both endpoints gone: unrecoverable
                        sr.failed = True
                        sr.finish_t = now2
                        if sr.first_token_t is None:
                            sr.first_token_t = now2
                        self.cluster.completed.append(sr)
                    return
                dst.inbound_reserved -= need
                sr.migrating = False
                # a migrated shared prefix re-imports as PRIVATE (the
                # wire shipped a plain contiguous copy) — matching
                # Engine.import_request; `need` above covered true length
                sr.cached_tokens = 0
                dst.adopt_running(sr, now2)

            self.cluster.events.push(now + pause, adopt)
            if on_finish:
                on_finish(True)

        inj = self.injector
        if inj is None:                # fault-free: the legacy event shape
            self.cluster.events.push(t + timing.total_s, finish)
            return
        fate = inj.transfer_event(sr.req.req_id)
        timeout = (self.cluster.cfg.migration_timeout_s
                   or timing.total_s * 4.0 + 1.0)

        def deadline():
            if state["settled"]:
                return                 # delivered in time
            state["settled"] = True
            # the payload never landed: free both endpoints' transfer
            # state; the request never left src.running
            src.migrations.finish(sr.req.req_id)
            release()
            sr.migrating = False
            if self.on_failed:
                self.on_failed(sr.req.req_id)

        self.cluster.events.push(t + timeout, deadline)
        if fate == XFER_LOST:
            return                     # vanishes; only the deadline fires
        deliver_at = (t + timeout * 2.0 if fate == XFER_STALL
                      else t + timing.total_s)
        self.cluster.events.push(deliver_at, finish)


# --------------------------------------------------------------------------
# CascadeInfer: discrete-event driver of the shared control plane
# --------------------------------------------------------------------------
class SimInstanceView:
    """`repro.control.protocol.InstanceView` over a simulated instance."""

    def __init__(self, inst: Instance):
        self.inst = inst
        self.id = inst.id

    def load(self) -> float:
        return self.inst.load()

    def free_tokens(self) -> float:
        return self.inst.free_tokens()

    def used_tokens(self) -> float:
        return self.inst.kv_tokens()

    def queued_tokens(self) -> float:
        return self.inst.queued_tokens()

    def capacity_weight(self) -> float:
        """Instance-units this simulated engine counts for (the tp ways
        its profile spans) — the plane's stage claiming and load
        normalization hook (DESIGN.md §Sharded serving)."""
        return float(self.inst.profile.num_devices)

    def requests(self) -> List[ReqView]:
        return [ReqView(sr, sr.req.req_id, float(sr.req.input_len),
                        float(sr.length), ctx_done=float(sr.ctx_done),
                        ctx_total=float(sr.prefill_target_len),
                        cached_tokens=float(sr.cached_tokens),
                        slo_class=sr.req.slo_class)
                for sr in self.inst.running if not sr.migrating]

    def prefix_digests(self) -> frozenset:
        return self.inst.prefix_digests()

    def tiered_digests(self):
        return self.inst.tiered_digests()

    def request_view(self):
        return self.inst.request_view()

    def has_request(self, sr: SimRequest) -> bool:
        return not sr.done and sr in self.inst.running

    def can_accept(self, sr: SimRequest) -> bool:
        return self.inst.free_tokens() >= self.inst.block_tokens(sr.length)

    def all_requests(self) -> List[ReqView]:
        """Every resident — running (even mid-migration), waiting, parked.
        Dead-instance recovery re-dispatches all of them."""
        return [ReqView(sr, sr.req.req_id, float(sr.req.input_len),
                        float(sr.length), ctx_done=float(sr.ctx_done),
                        ctx_total=float(sr.prefill_target_len),
                        cached_tokens=float(sr.cached_tokens),
                        slo_class=sr.req.slo_class)
                for sr in (list(self.inst.running) + list(self.inst.waiting)
                           + list(self.inst.parked))]


class _SimOps:
    """`repro.control.protocol.ClusterOps` over the simulated cluster:
    placements become queue pushes at the current event time, migrations
    become `TransferFabric` live transfers with cost-model timing."""

    def __init__(self, cluster: Cluster, fabric: TransferFabric):
        self.cluster = cluster
        self.fabric = fabric
        self.plane: Optional[ControlPlane] = None   # set after construction

    def dispatch(self, sr: SimRequest, instance_id: int) -> None:
        self.cluster.instances[instance_id].enqueue(sr,
                                                    self.cluster.events.now)

    def start_migration(self, sr: SimRequest, src_id: int,
                        dst_id: int) -> str:
        self.fabric.transfer(
            self.cluster.instances[src_id], self.cluster.instances[dst_id],
            sr, self.cluster.events.now,
            on_finish=lambda arrived: self.plane.migration_finished(
                sr.req.req_id, arrived))
        return MIG_STARTED

    def set_boundary(self, stage_idx: int, hi: float) -> None:
        pass                        # the core's bounds are authoritative

    # ---- fault tolerance (DESIGN.md §Fault tolerance) --------------------
    def redispatch(self, sr: SimRequest, instance_id: int) -> bool:
        """Recover a resident of a dead instance: its KV died, so replay
        prompt + generated-so-far through prefill on ``instance_id`` —
        the same resume math recompute preemption uses, so timing (and,
        on the real engine, tokens) match a never-crashed run."""
        now = self.cluster.events.now
        sr.migrating = False
        sr.redispatches += 1
        if sr.resume_target is None and sr.generated > 0:
            sr.resume_target = max(sr.req.input_len + sr.generated - 1, 1)
        sr.ctx_done = 0
        sr.cached_tokens = 0
        self.cluster.instances[instance_id].enqueue(sr, now)
        return True

    def fail_request(self, sr: SimRequest) -> None:
        now = self.cluster.events.now
        sr.failed = True
        sr.migrating = False
        sr.finish_t = now
        if sr.first_token_t is None:
            sr.first_token_t = now
        # completion (of a sort): the drain loop must terminate
        self.cluster.completed.append(sr)

    def instance_down(self, instance_id: int) -> None:
        self.cluster.instances[instance_id].clear_crashed()


class CascadePolicy(Policy):
    """The paper's system. Ablation knobs:
      refinement ∈ {adaptive, quantity, memory, none}   (Fig. 15)
      balancing  ∈ {full, inter-stage, rr}              (Fig. 16)
      plan layout chain/no-pipeline comes from the plan (Fig. 14)

    All knobs and mechanisms live in the shared `ControlPlane`; this class
    only adapts them to discrete-event time and simulated KV transfers.
    """
    name = "cascade"

    def __init__(self, plan: PipelinePlan, qoe: QoEModel, *,
                 refinement: str = "adaptive", balancing: str = "full",
                 kv_bytes_per_token: Optional[float] = None):
        self.plan = plan
        self.qoe = qoe
        self.refinement = refinement
        self.balancing = balancing
        self.kv_bytes_per_token = kv_bytes_per_token

    def attach(self, cluster):
        super().attach(cluster)
        ccfg = cluster.cfg
        fabric = TransferFabric(cluster, self.kv_bytes_per_token)
        ops = _SimOps(cluster, fabric)
        self.plane = ControlPlane(
            self.plan, self.qoe,
            ControlConfig(policy="cascade", refinement=self.refinement,
                          balancing=self.balancing, seed=ccfg.seed,
                          suspect_after=ccfg.suspect_after_s,
                          dead_after=ccfg.dead_after_s,
                          redispatch_budget=ccfg.redispatch_budget),
            ops=ops, instances=[SimInstanceView(i)
                                for i in cluster.instances])
        ops.plane = self.plane
        fabric.injector = cluster.injector
        fabric.on_failed = self.plane.migration_failed

    @property
    def stage_of_instance(self) -> List[int]:
        return [self.plane.stage_of_instance[i.id]
                for i in self.cluster.instances]

    # ---- driver events ------------------------------------------------------
    def _prefix_hint(self, sr: SimRequest):
        """(digest, best cached tokens, promote price in token units)
        across the cluster — the sim's mirror of MILSServer._prefix_hint
        (group id stands in for the content-derived head digest;
        membership patterns match, which is all routing consumes). Ties
        on cached tokens prefer the cheaper (device-warm) instance, and
        the promote price comes from the SAME pure pricing fn
        (`kernels.cost.promote_cost_tokens`) the server calls, so the
        decision logs stay comparable."""
        if sr.req.prefix_group < 0:
            return None, 0.0, 0.0
        cached, price = 0.0, 0.0
        for i in self.cluster.instances:
            c = float(i.cached_tokens_for(sr))
            p = promote_cost_tokens(i.host_blocks_for(sr), i.block_size)
            if (c, -p) > (cached, -price):
                cached, price = c, p
        digest = sr.req.prefix_group
        return digest, cached, price

    def dispatch(self, sr: SimRequest, t: float) -> None:
        digest, cached, price = self._prefix_hint(sr)
        self.plane.submit(sr, sr.req.req_id, sr.length,
                          cached_tokens=cached, prefix_digest=digest,
                          promote_cost_tokens=price,
                          slo_class=sr.req.slo_class)

    def on_iteration_end(self, inst, t):
        self.plane.on_instance_iteration(inst.id)

    def _heartbeat(self, t):
        """Liveness pulse (faulty runs only — fault-free event queues stay
        byte-identical to the legacy simulator): every live instance
        proves life, then the plane ages the silent ones."""
        for inst in self.cluster.instances:
            if inst.alive:
                self.plane.heartbeat(inst.id, t)
        self.plane.check_liveness(t)

    def timers(self):
        out = [(self.cluster.cfg.pump_interval,
                lambda t: self.plane.pump_all())]
        if self.balancing == "full":
            out.append((self.cluster.cfg.balance_interval,
                        lambda t: self.plane.balance()))
        if self.refinement != "none":
            out.append((self.cluster.cfg.refine_interval,
                        lambda t: self.plane.refine()))
        if self.cluster.cfg.faults is not None:
            out.append((self.cluster.cfg.heartbeat_interval,
                        self._heartbeat))
        return out
