"""End-to-end experiment driver: profile -> fit QoE -> plan pipeline ->
run all policies on the same workload. This is what the benchmarks call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage, full_dp, two_phase
from repro.core.qoe import QoEModel
from repro.core.workload_stats import build_stats, exp_bucket_edges
from repro.sim.cluster import (CascadePolicy, Cluster, ClusterConfig,
                               LlumnixLikePolicy, Policy, RoundRobinPolicy)
from repro.sim.costmodel import HardwareProfile, profile_from_config
from repro.sim.metrics import SimResult
from repro.sim.profiler import profile_and_fit
from repro.sim.workload import (Request, WorkloadSpec, generate,
                                generate_shared_prefix, generate_slo,
                                longtail_spec, sample_lengths,
                                shared_prefix_spec, slo_spec)


@functools.lru_cache(maxsize=8)
def fitted_qoe(arch: str, tp: int = 1, horizon_s: float = 8.0) -> QoEModel:
    """Profile-and-fit, cached per arch (deterministic)."""
    prof = profile_from_config(get_config(arch), tp=tp)
    return profile_and_fit(prof, horizon_s=horizon_s)


def plan_pipeline(arch: str, qoe: QoEModel, E: int, *,
                  planning_requests: Optional[Sequence] = None,
                  seed: int = 1, solver: str = "two_phase",
                  bandwidth: float = 25e9) -> PipelinePlan:
    """Offline pipeline planning from historical workload statistics."""
    cfg = get_config(arch)
    prof = profile_from_config(cfg)
    if planning_requests is None:
        spec = WorkloadSpec(rate=1.0, duration=1.0, seed=seed)
        rng = np.random.default_rng(seed)
        ins, outs = sample_lengths(spec, 2000, rng)
        planning_requests = list(zip(ins.tolist(), outs.tolist()))
    stats = build_stats(planning_requests, exp_bucket_edges(131_072))
    kvb = prof.kv_bytes_per_token or 2e5
    solve = two_phase if solver == "two_phase" else full_dp
    return solve(stats, E, qoe, kv_bytes_per_token=kvb, bandwidth=bandwidth)


def chain_plan(arch: str, qoe: QoEModel, E: int, *,
               seed: int = 1) -> PipelinePlan:
    """Fig.-14 'chain' ablation: one instance per pipeline stage — the
    paper's phase-1 DP without the merge phase."""
    from repro.core.partition import _chain_dp
    cfg = get_config(arch)
    prof = profile_from_config(cfg)
    spec = WorkloadSpec(rate=1.0, duration=1.0, seed=seed)
    rng = np.random.default_rng(seed)
    ins, outs = sample_lengths(spec, 2000, rng)
    stats = build_stats(list(zip(ins.tolist(), outs.tolist())),
                        exp_bucket_edges(131_072))
    stages = _chain_dp(stats, E, qoe, prof.kv_bytes_per_token or 2e5, 25e9)
    stages[-1] = Stage(stages[-1].lo, float("inf"), 1)
    return PipelinePlan(stages=stages, quality=float("nan"))


def no_pipeline_plan(E: int) -> PipelinePlan:
    """Fig.-14 'no-pipeline' ablation: all instances in one stage."""
    return PipelinePlan(stages=[Stage(0.0, float("inf"), E)],
                        quality=float("nan"))


def make_policy(kind: str, arch: str, E: int, *, qoe=None, plan=None,
                **kw) -> Policy:
    if kind == "round-robin":
        return RoundRobinPolicy()
    if kind == "llumnix":
        return LlumnixLikePolicy()
    qoe = qoe or fitted_qoe(arch)
    plan = plan or plan_pipeline(arch, qoe, E)
    return CascadePolicy(plan, qoe, **kw)


def run_policy(arch: str, policy: Policy, requests: Sequence[Request],
               duration: float, *, E: int = 16,
               capacity_tokens: float = 400_000.0, seed: int = 0,
               tp: int = 1, ragged_backend: bool = False,
               bandwidth: float = 25e9,
               prefill_token_budget: Optional[int] = None,
               prefix_cache: bool = True,
               preemption: bool = True,
               host_kv_budget: int = 0,
               faults=None,
               migration_timeout_s: Optional[float] = None) -> SimResult:
    prof = profile_from_config(get_config(arch), tp=tp,
                               ragged_backend=ragged_backend)
    cfg = ClusterConfig(num_instances=E, capacity_tokens=capacity_tokens,
                        seed=seed, bandwidth=bandwidth,
                        prefill_token_budget=prefill_token_budget,
                        prefix_cache=prefix_cache,
                        preemption=preemption,
                        host_kv_budget=host_kv_budget, faults=faults,
                        migration_timeout_s=migration_timeout_s)
    cluster = Cluster(prof, policy, cfg)
    return cluster.run(requests, duration)


def compare_policies(arch: str, rate: float, duration: float, *,
                     E: int = 16, seed: int = 0,
                     capacity_tokens: float = 400_000.0,
                     workload: str = "sharegpt",
                     prefill_token_budget: Optional[int] = None,
                     prefix_cache: bool = True,
                     preemption: bool = True,
                     host_kv_budget: int = 0,
                     kinds: Sequence[str] = ("round-robin", "llumnix",
                                             "cascade")) -> Dict[str, SimResult]:
    """Same workload, all policies — the Fig. 6/7/10 experiment.

    ``workload="longtail"`` swaps in the 32K–128K-prompt-tail trace
    (``sim.workload.longtail_spec``) and ``prefill_token_budget`` runs the
    instances with chunked mixed iterations — the long-context scenario
    chunked prefill targets. ``workload="shared_prefix"`` runs the
    system-prompt/multi-turn trace (``sim.workload.shared_prefix_spec``)
    with the group-granular prefix-cache mirror — the cascade-vs-baseline
    comparison under prefix caching (``prefix_cache=False`` ablates it).
    ``workload="slo"`` runs the open-loop SLO-class mix with diurnal +
    bursty arrivals (``sim.workload.slo_spec``) — the goodput-under-SLO
    experiment (``preemption=False`` ablates the tiered scheduler back
    to FCFS). ``host_kv_budget`` (tokens per instance) turns on the
    multi-tier KV mirror — idle published prefixes pin device capacity
    until pressure demotes them to a bounded host store, and hits on
    demoted groups pay the promote staging price — so shared_prefix runs
    become tiering policy experiments (DESIGN.md §Multi-tier KV)."""
    if workload == "longtail":
        requests = generate(longtail_spec(rate, duration, seed=seed))
    elif workload == "slo":
        requests = generate_slo(slo_spec(rate, duration, seed=seed))
        if prefill_token_budget is None:
            prefill_token_budget = 512
    elif workload == "shared_prefix":
        requests = generate_shared_prefix(
            shared_prefix_spec(rate, duration, seed=seed))
        if prefill_token_budget is None:        # caching needs chunking
            prefill_token_budget = 512
    else:
        requests = generate(WorkloadSpec(rate=rate, duration=duration,
                                         seed=seed))
    out = {}
    for kind in kinds:
        pol = make_policy(kind if kind != "cascade" else "cascade",
                          arch, E)
        out[kind] = run_policy(arch, pol, requests, duration, E=E,
                               capacity_tokens=capacity_tokens, seed=seed,
                               prefill_token_budget=prefill_token_budget,
                               prefix_cache=prefix_cache,
                               preemption=preemption,
                               host_kv_budget=host_kv_budget)
    return out
