"""Discrete-event core: a heap-ordered event queue with stable ties."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class EventQueue:
    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._tie = itertools.count()
        self.now = 0.0

    def push(self, time: float, fn: Callable[[], None]) -> None:
        assert time >= self.now - 1e-12, (time, self.now)
        heapq.heappush(self._heap, (time, next(self._tie), fn))

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, t_end)

    def __len__(self) -> int:
        return len(self._heap)
