"""SLO-tiered scheduling: per-request service classes, deadline- and
size-aware queue ordering, and the park-vs-recompute preemption policy
(DESIGN.md §SLO scheduling & preemption).

The package is deliberately backend-free: `repro.serving.engine.Engine`
and `repro.sim.instance.Instance` both order their waiting queues with
`queue_key`/`insert_sorted` and price preemption with
`park_or_recompute`, so the sim remains a faithful mirror of the real
engine's scheduling decisions.
"""
from .slo import (SLO_CLASSES, DEFAULT_CLASS, SLOSpec, slo_of, priority_of,
                  queue_key, insert_sorted, parse_class_mix, assign_classes)
from .policy import (PARK_RESTORE_COST_S, recompute_cost_s,
                     park_or_recompute)

__all__ = [
    "SLO_CLASSES", "DEFAULT_CLASS", "SLOSpec", "slo_of", "priority_of",
    "queue_key", "insert_sorted", "parse_class_mix", "assign_classes",
    "PARK_RESTORE_COST_S", "recompute_cost_s", "park_or_recompute",
]
