"""SLO classes and the deadline- and size-aware queue order.

Three service classes (the menu of Slice-Level Scheduling / "Optimal
Scheduling Algorithms for LLM Inference", PAPERS.md), each with a TTFT
and a TPOT deadline in abstract seconds — the sim's native clock. The
real server measures time in steps and converts with a
``slo_time_scale`` (steps per abstract second), so one spec drives both
backends.

The waiting-queue order is ``queue_key``: strict priority first, then
the request's TTFT *deadline* (arrival + budget — earliest-deadline-
first within a class), then size (shortest-job-first tie-break), then a
submission sequence number. With a uniform class and distinct arrival
times this degenerates to exact FCFS, which is what makes preemptive
scheduling safe to enable by default: legacy single-class traffic sees
byte-identical behaviour.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One service class: smaller ``priority`` is served first."""
    name: str
    priority: int
    ttft_slo: float      # time-to-first-token budget (abstract seconds)
    tpot_slo: float      # per-output-token budget (abstract seconds)


SLO_CLASSES: Dict[str, SLOSpec] = {
    "interactive": SLOSpec("interactive", 0, ttft_slo=0.5, tpot_slo=0.05),
    "standard": SLOSpec("standard", 1, ttft_slo=2.0, tpot_slo=0.2),
    "batch": SLOSpec("batch", 2, ttft_slo=30.0, tpot_slo=2.0),
}
DEFAULT_CLASS = "standard"


def slo_of(slo_class: str) -> SLOSpec:
    """Spec for a class name; unknown names fall back to ``standard``."""
    return SLO_CLASSES.get(slo_class, SLO_CLASSES[DEFAULT_CLASS])


def priority_of(slo_class: str) -> int:
    return slo_of(slo_class).priority


def queue_key(slo_class: str, arrival: float, size: float, seq: int,
              *, time_scale: float = 1.0,
              promote: int = 0) -> Tuple[int, float, float, int]:
    """Waiting-queue sort key: (priority, TTFT deadline, size, seq).

    ``time_scale`` converts the spec's abstract-seconds budget into the
    caller's clock (1.0 for the sim, steps-per-second for the engine).

    ``promote`` is the starvation/aging guard (DESIGN.md §SLO sched): a
    recompute-preempted request that keeps waiting climbs one priority
    class per promotion step, floored at the top class — so saturated
    high-class traffic can delay but never permanently starve a victim.
    """
    spec = slo_of(slo_class)
    deadline = float(arrival) + spec.ttft_slo * float(time_scale)
    return (max(spec.priority - int(promote), 0), deadline,
            float(size), int(seq))


def aging_promotion(slo_class: str, preempted_at: float, now: float,
                    *, time_scale: float = 1.0) -> int:
    """Starvation guard for recompute-preempted requests: priority
    classes earned by queue age — one per full TTFT budget elapsed since
    the preemption. A just-preempted request keeps its class (promotion
    0, bit-identical short-run behavior); one that has waited a whole
    TTFT budget outranks fresh same-class arrivals, and after enough
    budgets it reaches the top class — so saturated high-class traffic
    can delay but never permanently starve a victim. Shared by the
    engine and the sim so decision logs stay comparable."""
    spec = slo_of(slo_class)
    budget = max(spec.ttft_slo * float(time_scale), 1e-9)
    return int(max(float(now) - float(preempted_at), 0.0) / budget)


def tpot_hopeless(slo_class: str, first_token: float, now: float,
                  total_new_tokens: int, *,
                  time_scale: float = 1.0) -> bool:
    """Has this decode already blown its TPOT deadline beyond recovery?

    True when even finishing the REMAINING tokens instantly could not
    bring the mean per-token latency back under ``tpot_slo``: the time
    already elapsed since the first token exceeds the budget for the
    request's entire output. Such a request is a lost cause for TPOT
    attainment — preempting healthy traffic to serve it buys nothing, so
    admission control skips it as a preemptor (it still runs and
    finishes; it just can't evict others)."""
    spec = slo_of(slo_class)
    budget = spec.tpot_slo * float(time_scale) * max(
        int(total_new_tokens) - 1, 1)
    return (float(now) - float(first_token)) > budget


def insert_sorted(queue: List, item) -> None:
    """Insert ``item`` into ``queue`` keeping it sorted by ``.sched_key``.

    Stable for equal keys (new item goes after existing equals), so a
    uniform-class stream with distinct seq numbers is plain FCFS.
    """
    keys = [q.sched_key for q in queue]
    queue.insert(bisect.bisect_right(keys, item.sched_key), item)


def parse_class_mix(text: str) -> Tuple[Tuple[str, float], ...]:
    """Parse ``"interactive=0.5,standard=0.3,batch=0.2"`` (``:`` also
    accepted as the separator) into normalized (class, weight) pairs.
    Raises on unknown classes or no mass."""
    pairs = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        sep = "=" if "=" in part else ":"
        name, _, w = part.partition(sep)
        name = name.strip()
        if name not in SLO_CLASSES:
            raise ValueError(f"unknown SLO class {name!r} "
                             f"(known: {sorted(SLO_CLASSES)})")
        pairs.append((name, float(w) if w else 1.0))
    total = sum(w for _, w in pairs)
    if not pairs or total <= 0:
        raise ValueError(f"empty or zero-mass class mix: {text!r}")
    return tuple((n, w / total) for n, w in pairs)


def assign_classes(n: int, mix: Sequence[Tuple[str, float]], rng) -> List[str]:
    """Draw ``n`` class labels i.i.d. from a (class, weight) mix."""
    names = [m[0] for m in mix]
    probs = [m[1] for m in mix]
    total = sum(probs)
    probs = [p / total for p in probs]
    idx = rng.choice(len(names), size=n, p=probs)
    return [names[int(i)] for i in idx]
