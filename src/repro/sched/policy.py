"""Park-vs-recompute: what to do with a preempted decode's KV.

Two exits for a victim (DESIGN.md §SLO scheduling & preemption):

* **park** — keep its KV blocks and allocator reservation, free only
  the batch slot. Zero restore cost beyond re-entering the batch (one
  extra kernel-launch epsilon), but frees no memory.
* **recompute** — release everything and re-enqueue the request with a
  resume prefix (prompt + generated-so-far); the chunked-prefill path
  rebuilds the KV. Frees ``victim_blocks`` immediately at the price of
  re-running prefill attention over ``kv_tokens`` rows.

The decision is priced by the same `kernels/cost.py` terms the engine
and sim already trust: ``recompute_cost_s`` sums
`prefill_chunk_attn_time_s` over the resume chunks, and parking's
restore price is one extra launch (`LAUNCH_OVERHEAD_S`). When the
preemption must actually free blocks (memory pressure, not just a slot
shortage) parking is useless and recompute is forced.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.kernels.cost import (AttnSpec, LAUNCH_OVERHEAD_S,
                                prefill_chunk_attn_time_s)

# Restoring a parked request costs one extra kernel launch worth of
# overhead (its blocks never moved); used as the recompute break-even.
PARK_RESTORE_COST_S = LAUNCH_OVERHEAD_S


def recompute_cost_s(kv_tokens: int, spec: AttnSpec,
                     chunk: int = 256) -> float:
    """Wall time to rebuild ``kv_tokens`` KV rows via chunked prefill."""
    kv_tokens = int(kv_tokens)
    if kv_tokens <= 0:
        return 0.0
    chunk = max(int(chunk), 1)
    t = 0.0
    for ctx in range(0, kv_tokens, chunk):
        t += prefill_chunk_attn_time_s(min(chunk, kv_tokens - ctx), ctx, spec)
    return t + math.ceil(kv_tokens / chunk) * LAUNCH_OVERHEAD_S


def park_or_recompute(*, must_free_blocks: int, kv_tokens: int,
                      spec: Optional[AttnSpec] = None,
                      chunk: int = 256) -> str:
    """Pick the victim's exit: ``"park"`` or ``"recompute"``.

    ``must_free_blocks > 0`` means the preemptor is blocked on memory,
    not just a slot — parking (which pins the victim's blocks) cannot
    help, so recompute is forced. Otherwise park unless the cost model
    says rebuilding the victim's KV is at least as cheap as the parked
    restore (true only for tiny contexts, where recompute also returns
    memory to the pool for free).
    """
    if must_free_blocks > 0:
        return "recompute"
    if spec is not None and (recompute_cost_s(kv_tokens, spec, chunk)
                             <= PARK_RESTORE_COST_S):
        return "recompute"
    return "park"
